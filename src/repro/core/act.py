"""Activation-compressed ops (the paper's core mechanism, §3.3).

Each op computes an EXACT full-precision forward; what differs from vanilla
autodiff is the *residual* it saves for the backward pass:

  vanilla:  save x (fp32)                  -> O(N*d*4) bytes
  TinyKG:   save Quant(x) (b-bit packed)   -> O(N*d*b/8) bytes  (+2 fp32/row)

The backward pass dequantizes and computes full-precision gradients, which
stay unbiased because the quantizer is unbiased (Proposition 1).

Ops mirror the paper's operator list (Linear/MM, ReLU, SPMM, nonlinearities,
norms) plus a generic ``act_remat`` wrapper (beyond-paper: checkpointing that
recomputes the forward from the *compressed* input, GACT-style), which is how
we ACT-ify whole transformer blocks with one call.

Linear ops only need their input saved to form the *weight* gradient
(∇Θ = x̂ᵀ ∇y); the data gradient uses only the weights. Purely index-based
linear ops (embedding lookup, fixed-adjacency SPMM) need no activation at
all — their residuals are indices, which autodiff already keeps compactly.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .context import current_context
from .policy import ACTPolicy, FP32
from .quant import QTensor, dequantize, quantize

__all__ = [
    "act_matmul",
    "act_dense",
    "act_relu",
    "act_nonlin",
    "act_rmsnorm",
    "act_spmm",
    "act_remat",
]


# ---------------------------------------------------------------------------
# context resolution (DESIGN.md §6)
#
# Every public op accepts explicit ``key=`` / ``policy=`` kwargs (the
# pre-context API, still first in precedence) and an optional ``scope=``
# site name. Whatever is omitted resolves from the ambient ActContext; with
# no context either, the policy defaults to FP32. The resolved site is
# recorded on the context (residual shape/bits) for traced memory
# accounting.
# ---------------------------------------------------------------------------


@functools.cache
def _dummy_key() -> jax.Array:
    # placeholder riding the op signature when no randomness is consumed
    # (inactive policy or nearest rounding)
    return jax.random.PRNGKey(0)


def _resolve_site(op_kind: str, scope: str | None, key,
                  policy: ACTPolicy | None, *, need_key: bool = True):
    """(scope, policy, key, ctx) for one op call; see block comment above."""
    ctx = current_context()
    name = None
    if ctx is not None:
        name = ctx.qualify(scope or op_kind)
        if policy is None:
            policy = ctx.policy_for(op_kind, name)
        if key is None:
            key = ctx.key_for(name)
    if policy is None:
        policy = FP32
    if key is None:
        if need_key and policy.requires_key:
            raise ValueError(
                f"act op {name or scope or op_kind!r}: stochastic rounding "
                "under an active policy needs a PRNG key — pass key=, or "
                "run inside act_context(..., root_key=...). (A fixed "
                "default key would replay identical rounding noise.)")
        key = _dummy_key()
    return name, policy, key, ctx


def _record(ctx, name, op_kind, shape, policy: ACTPolicy) -> None:
    # bits=None prices the uncompressed fp32 residual — what vanilla
    # autodiff buffers when the policy is inactive/disabled
    if ctx is not None and name is not None:
        ctx.record(name, op_kind, shape,
                   policy.bits if policy.active else None)


def _maybe_quantize(x: jax.Array, key: jax.Array, policy: ACTPolicy):
    """QTensor under an active policy, raw tensor otherwise (FP32 baseline)."""
    if policy.active:
        if policy.kernel == "pallas":
            from repro.kernels import ops as kops

            return kops.quantize(x, key, bits=policy.bits,
                                 stochastic=policy.stochastic)
        return quantize(x, key, bits=policy.bits, stochastic=policy.stochastic)
    return x


def _maybe_dequantize(q) -> jax.Array:
    if isinstance(q, QTensor):
        return dequantize(q)
    return q


# ---------------------------------------------------------------------------
# matmul / dense
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _act_matmul(policy: ACTPolicy, x, w, key):
    return jnp.einsum("...k,kn->...n", x, w)


def _act_matmul_fwd(policy, x, w, key):
    out = jnp.einsum("...k,kn->...n", x, w)
    return out, (_maybe_quantize(x, key, policy), w)


def _act_matmul_bwd(policy, res, g):
    qx, w = res
    xhat = _maybe_dequantize(qx)
    dx = jnp.einsum("...n,kn->...k", g, w)
    if policy.active and policy.kernel == "pallas":
        from repro.kernels import ops as kops

        dw = kops.dequant_matmul(qx, g)  # fused dequant + Ĥᵀ∇J GEMM
    else:
        dw = jnp.einsum("...k,...n->kn", xhat, g)
    return dx, dw, None


_act_matmul.defvjp(_act_matmul_fwd, _act_matmul_bwd)


def act_matmul(x, w, *, key=None, policy: ACTPolicy | None = None,
               scope: str | None = None):
    """``x @ w`` with b-bit residual storage of ``x``.

    ``key``/``policy`` omitted resolve from the ambient ``ActContext`` at
    the site named ``scope`` (default ``"matmul"``); see DESIGN.md §6.
    """
    name, policy, key, ctx = _resolve_site("matmul", scope, key, policy)
    _record(ctx, name, "matmul", x.shape, policy)
    if not policy.enabled:
        return jnp.einsum("...k,kn->...n", x, w)
    return _act_matmul(policy, x, w, key)


def act_dense(x, w, b, *, key=None, policy: ACTPolicy | None = None,
              scope: str | None = None):
    """Affine layer; bias grad needs no activation so it rides for free."""
    out = act_matmul(x, w, key=key, policy=policy, scope=scope)
    if b is not None:
        out = out + b
    return out


# ---------------------------------------------------------------------------
# elementwise nonlinearities
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _act_relu(x):
    return jnp.maximum(x, 0)


def _act_relu_fwd(x):
    mask = x > 0
    return jnp.where(mask, x, 0), mask  # bool mask: 1 bit/elt in principle


def _act_relu_bwd(mask, g):
    return (jnp.where(mask, g, 0),)


_act_relu.defvjp(_act_relu_fwd, _act_relu_bwd)


def act_relu(x, *, scope: str | None = None):
    """ReLU with a 1-bit exact mask residual (paper §4.1.4) — lossless.

    Policy-independent (the mask is exact at any bit-width); under an
    ambient context the mask still shows up in the residual trace.
    """
    ctx = current_context()
    if ctx is not None:
        ctx.record(ctx.qualify(scope or "relu"), "relu", x.shape, 1,
                   exact_mask=True)
    return _act_relu(x)


def _d_silu(x):
    s = jax.nn.sigmoid(x)
    return s * (1 + x * (1 - s))


def _d_gelu(x):
    # tanh-approx gelu derivative
    c = jnp.sqrt(2 / jnp.pi)
    t = jnp.tanh(c * (x + 0.044715 * x**3))
    dt = (1 - t**2) * c * (1 + 3 * 0.044715 * x**2)
    return 0.5 * (1 + t) + 0.5 * x * dt


def _gelu(x):
    c = jnp.sqrt(2 / jnp.pi)
    return 0.5 * x * (1 + jnp.tanh(c * (x + 0.044715 * x**3)))


_NONLIN: dict[str, tuple[Callable, Callable]] = {
    "silu": (jax.nn.silu, _d_silu),
    "gelu": (_gelu, _d_gelu),
    "tanh": (jnp.tanh, lambda x: 1 - jnp.tanh(x) ** 2),
    "sigmoid": (jax.nn.sigmoid,
                lambda x: jax.nn.sigmoid(x) * (1 - jax.nn.sigmoid(x))),
    "leaky_relu": (lambda x: jnp.where(x > 0, x, 0.01 * x),
                   lambda x: jnp.where(x > 0, 1.0, 0.01)),
}


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _act_nonlin(name: str, policy: ACTPolicy, x, key):
    return _NONLIN[name][0](x)


def _act_nonlin_fwd(name, policy, x, key):
    return _NONLIN[name][0](x), _maybe_quantize(x, key, policy)


def _act_nonlin_bwd(name, policy, qx, g):
    xhat = _maybe_dequantize(qx)
    return g * _NONLIN[name][1](xhat), None


_act_nonlin.defvjp(_act_nonlin_fwd, _act_nonlin_bwd)


def act_nonlin(x, *, fn: str, key=None, policy: ACTPolicy | None = None,
               scope: str | None = None):
    """Elementwise nonlinearity saving a quantized copy of its input."""
    name, policy, key, ctx = _resolve_site("nonlin", scope or fn, key, policy)
    _record(ctx, name, "nonlin", x.shape, policy)
    if not policy.enabled:
        return _NONLIN[fn][0](x)
    return _act_nonlin(fn, policy, x, key)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _act_rmsnorm(policy: ACTPolicy, x, gamma, key, eps):
    r = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x * r * gamma


def _act_rmsnorm_fwd(policy, x, gamma, key, eps):
    r = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x * r * gamma, (_maybe_quantize(x, key, policy), gamma, eps)


def _act_rmsnorm_bwd(policy, res, g):
    qx, gamma, eps = res
    xhat = _maybe_dequantize(qx).astype(jnp.float32)
    gf = g.astype(jnp.float32)
    d = xhat.shape[-1]
    r = jax.lax.rsqrt(jnp.mean(xhat * xhat, axis=-1, keepdims=True) + eps)
    gg = gf * gamma.astype(jnp.float32)
    dot = jnp.sum(gg * xhat, axis=-1, keepdims=True)
    dx = r * gg - (r**3 / d) * dot * xhat
    dgamma = jnp.sum(gf * xhat * r, axis=tuple(range(g.ndim - 1)))
    return dx.astype(g.dtype), dgamma.astype(gamma.dtype), None, None


_act_rmsnorm.defvjp(_act_rmsnorm_fwd, _act_rmsnorm_bwd)


def act_rmsnorm(x, gamma, *, key=None, policy: ACTPolicy | None = None,
                scope: str | None = None, eps: float = 1e-6):
    """RMSNorm storing its input quantized; rstd recomputed from x̂ in bwd."""
    name, policy, key, ctx = _resolve_site("rmsnorm", scope, key, policy)
    _record(ctx, name, "rmsnorm", x.shape, policy)
    if not policy.enabled:
        r = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
        return x * r * gamma
    return _act_rmsnorm(policy, x, gamma, key, eps)


# ---------------------------------------------------------------------------
# SPMM (KG message passing) — the paper's headline op (Eq. 2)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _act_spmm(policy: ACTPolicy, num_nodes: int, x, src, dst, ew, key):
    msgs = x[src] * ew[:, None]
    return jax.ops.segment_sum(msgs, dst, num_segments=num_nodes)


def _act_spmm_fwd(policy, num_nodes, x, src, dst, ew, key):
    msgs = x[src] * ew[:, None]
    out = jax.ops.segment_sum(msgs, dst, num_segments=num_nodes)
    # x is needed only for ∇ew (edge weights, e.g. KGAT attention); indices
    # alone suffice for ∇x. Save x quantized.
    return out, (_maybe_quantize(x, key, policy), src, dst, ew)


def _act_spmm_bwd(policy, num_nodes, res, g):
    qx, src, dst, ew = res
    xhat = _maybe_dequantize(qx)
    g_at_dst = g[dst]  # (E, d)
    # scatter to x's OWN row count — x may be a gathered (global) table
    # while num_nodes is the (local) output segment count (shard_map path)
    dx = jax.ops.segment_sum(g_at_dst * ew[:, None], src,
                             num_segments=xhat.shape[-2])
    dew = jnp.sum(xhat[src] * g_at_dst, axis=-1)
    return dx, None, None, dew, None


_act_spmm.defvjp(_act_spmm_fwd, _act_spmm_bwd)


# -- fused Pallas path: blocked-CSR layout, no (E, d) message tensor --------
#
# The layout pytree is flattened into explicit array args (custom_vjp
# forbids closed-over tracers and integer leaves take None cotangents,
# same as src/dst above); its treedef rides as a static nondiff arg.


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _act_spmm_pallas(policy: ACTPolicy, treedef, x, ew, key, *leaves):
    from repro.kernels import ops as kops

    layout = jax.tree_util.tree_unflatten(treedef, leaves)
    return kops.spmm(x, ew, layout)


def _act_spmm_pallas_fwd(policy, treedef, x, ew, key, *leaves):
    from repro.kernels import ops as kops

    layout = jax.tree_util.tree_unflatten(treedef, leaves)
    out = kops.spmm(x, ew, layout)
    return out, (_maybe_quantize(x, key, policy), ew, leaves)


def _act_spmm_pallas_bwd(policy, treedef, res, g):
    from repro.kernels import ops as kops

    qx, ew, leaves = res
    layout = jax.tree_util.tree_unflatten(treedef, leaves)
    # ∇x: scatter-transpose — the same fused kernel on the src-sorted
    # direction of the layout (all-gatherᵀ analogue, no (E, d) tensor)
    dx = kops.spmm(g, ew, layout, transpose=True).astype(g.dtype)
    # ∇ew: fused dequant-SDDMM reading the packed residual directly
    dew = kops.spmm_grad_ew(qx, g, layout).astype(ew.dtype)
    return (dx, dew, None) + (None,) * len(leaves)


_act_spmm_pallas.defvjp(_act_spmm_pallas_fwd, _act_spmm_pallas_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _spmm_linear_pallas(treedef, x, *leaves):
    from repro.kernels import ops as kops

    return kops.spmm(x, None, jax.tree_util.tree_unflatten(treedef, leaves))


def _spmm_linear_pallas_fwd(treedef, x, *leaves):
    from repro.kernels import ops as kops

    out = kops.spmm(x, None, jax.tree_util.tree_unflatten(treedef, leaves))
    return out, leaves


def _spmm_linear_pallas_bwd(treedef, leaves, g):
    from repro.kernels import ops as kops

    layout = jax.tree_util.tree_unflatten(treedef, leaves)
    dx = kops.spmm(g, None, layout, transpose=True)
    return (dx,) + (None,) * len(leaves)


_spmm_linear_pallas.defvjp(_spmm_linear_pallas_fwd, _spmm_linear_pallas_bwd)


# The SPMM kernels keep the whole node table VMEM-resident, blocked over
# features only (see DESIGN.md §4). On a real TPU that bounds the graphs
# they can serve; oversized tables must take the jnp fallback rather than
# fail Mosaic compilation mid-training. Budget: the x and g tables both
# ride in VMEM at block_d <= 512 fp32 lanes, against ~16 MB/core.
_VMEM_TABLE_BUDGET = 8 * 1024 * 1024


def _pallas_layout_ok(layout, x, src, num_nodes: int) -> bool:
    """Fused-kernel eligibility; anything else falls back to jnp."""
    if layout is None or x.ndim != 2:
        return False
    if x.dtype not in (jnp.float32, jnp.bfloat16):
        return False
    m = layout.meta
    if not (m.n_edges == src.shape[0] and m.n_dst == num_nodes
            and m.n_src == x.shape[0]):
        return False
    from repro.kernels import ops as kops

    if not kops.INTERPRET:
        block_d = min(x.shape[1], 512)
        if (m.n_src + m.n_dst) * block_d * 4 > _VMEM_TABLE_BUDGET:
            return False
    return True


def act_spmm(x, src, dst, ew, *, num_nodes: int, key=None,
             policy: ACTPolicy | None = None, scope: str | None = None,
             layout=None):
    """Weighted sparse aggregation ``H[v] = Σ_{(u,r,v)} w_e · x[u]``.

    ``src``/``dst`` are int edge endpoints, ``ew`` per-edge weights. When
    ``ew`` is None (plain normalized adjacency, e.g. GCN/KGCN) the op is
    linear with index-only residuals — nothing to compress, handled exactly
    (and nothing is recorded in the residual trace).

    ``layout`` is an optional blocked-CSR ``repro.data.csr.SpmmLayout``
    for the same edge list. Under ``ACTPolicy(kernel="pallas")`` it routes
    forward, ∇x and ∇ew through the fused Pallas kernels (no ``(E, d)``
    message tensor in HBM). The automatic jnp fallback covers *shape*
    mismatches only — a missing layout, different edge/node counts, or
    an unsupported dtype. A layout built for a *different edge list of
    the same sizes* is indistinguishable at trace time and would
    silently aggregate along the wrong edges: the caller owns keeping
    ``layout`` in sync with ``src``/``dst`` (``CKG.layout`` rides inside
    the graph pytree precisely so they travel together).
    """
    name, policy, key, ctx = _resolve_site("spmm", scope, key, policy,
                                           need_key=ew is not None)
    fused = policy.kernel == "pallas" and \
        _pallas_layout_ok(layout, x, src, num_nodes)
    if ew is None:
        if fused:
            leaves, treedef = jax.tree_util.tree_flatten(layout)
            return _spmm_linear_pallas(treedef, x, *leaves)
        msgs = x[src]
        return jax.ops.segment_sum(msgs, dst, num_segments=num_nodes)
    _record(ctx, name, "spmm", x.shape, policy)
    if not policy.enabled:
        msgs = x[src] * ew[:, None]
        return jax.ops.segment_sum(msgs, dst, num_segments=num_nodes)
    if fused:
        leaves, treedef = jax.tree_util.tree_flatten(layout)
        return _act_spmm_pallas(policy, treedef, x, ew, key, *leaves)
    return _act_spmm(policy, num_nodes, x, src, dst, ew, key)


# ---------------------------------------------------------------------------
# Generic compressed-checkpoint wrapper (beyond-paper, GACT-style)
# ---------------------------------------------------------------------------


def act_remat(fn: Callable, policy: ACTPolicy | None = None, *,
              scope: str | None = None, repeat: int = 1):
    """Wrap ``fn(params, x, consts) -> y`` to save only Quant(x) backward.

    The backward pass dequantizes x̂ and *recomputes* ``fn`` under ``jax.vjp``
    — i.e. gradient checkpointing whose checkpoint is b-bit compressed. One
    wrapper ACT-ifies an entire block (attention + MLP) with O(N·d·b/8)
    residual memory instead of O(layers · activations).

    ``consts`` is a non-differentiated pytree (positions, masks, …) passed
    as an explicit argument — custom_vjp forbids closed-over tracers.
    Returns ``wrapped(params, x, key=None, consts=None)``; under an
    inactive policy it degrades to plain ``jax.checkpoint`` (the FP32
    baseline). Like every other act op, ``policy=None`` resolves from the
    ambient context at CALL time (site ``scope`` / ``"remat"``), so a
    block wrapped outside any context still honors the schedule it is
    later applied under; the quantized-input save is recorded per apply.
    ``repeat`` is for callers that apply the wrapped fn under
    ``jax.lax.scan`` (one trace, ``repeat`` runtime applications): the
    residual trace then carries one record per buffered instance.
    """

    explicit_policy = policy

    @functools.lru_cache(maxsize=None)
    def active_path(pol: ACTPolicy):
        # one custom_vjp instance per resolved policy (hashable dataclass)
        @jax.custom_vjp
        def wrapped(params, x, key, consts):
            return fn(params, x, consts)

        def fwd(params, x, key, consts):
            return fn(params, x, consts), (
                params, _maybe_quantize(x, key, pol), consts)

        def bwd(res, g):
            params, qx, consts = res
            xhat = _maybe_dequantize(qx)
            _, vjp = jax.vjp(lambda p, xx: fn(p, xx, consts), params, xhat)
            dparams, dx = vjp(g)
            return dparams, dx, None, None

        wrapped.defvjp(fwd, bwd)
        return wrapped

    baseline = None  # lazy jax.checkpoint, shared across applies

    def apply(params, x, key=None, consts=None):
        nonlocal baseline
        name, pol, key, ctx = _resolve_site("remat", scope, key,
                                            explicit_policy)
        _record(ctx, name, "remat", x.shape, pol)
        for i in range(1, repeat):  # scan buffers `repeat` instances
            _record(ctx, None if name is None else f"{name}[{i}]",
                    "remat", x.shape, pol)
        if not pol.active:
            if baseline is None:
                baseline = jax.checkpoint(
                    lambda params, x, consts: fn(params, x, consts))
            return baseline(params, x, consts)
        return active_path(pol)(params, x, key, consts)

    return apply
