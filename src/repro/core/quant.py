"""TinyKG uniform quantization with stochastic rounding (paper Eq. 3/4).

Quantize:   q = floor_sr((x - Z) / R * B)          with B = 2^b - 1 bins
Dequantize: x_hat = R * q / B + Z

Per-row granularity follows the paper: each activation row ``e_v in R^d``
(the last axis) gets its own range ``R_v = max - min`` and zero ``Z_v = min``.
Proposition 1: the quantizer is unbiased, Var[x_hat] <= d * R^2 / (4 B^2).

Sub-byte codes are bit-packed so the stored residual is genuinely ``b/8``
bytes per element (plus two fp32 scalars per row), matching the paper's
CUDA bit-stream packing — here with vectorized shift/OR over uint8 lanes.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

__all__ = [
    "QTensor",
    "quantize",
    "dequantize",
    "pack_bits",
    "unpack_bits",
    "stochastic_round",
    "nearest_round",
    "act_bytes",
]

_EPS = 1e-12  # guards R == 0 rows (constant rows quantize to code 0 exactly)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QTensor:
    """A bit-packed quantized activation.

    packed : uint8 array, shape ``(*leading, ceil(d * bits / 8))``
    scale  : fp32 ``R / B`` per row, shape ``(*leading, 1)``
    zero   : fp32 ``Z`` per row, shape ``(*leading, 1)``
    bits   : static int in {1, 2, 4, 8}
    dim    : static int, original last-axis size d (needed to strip pad)
    dtype  : original dtype to restore on dequantize

    ``bits``/``dim``/``dtype`` are pytree aux data (static under jit).
    """

    packed: jax.Array
    scale: jax.Array
    zero: jax.Array
    bits: int
    dim: int
    dtype: jnp.dtype

    def tree_flatten(self):
        return (self.packed, self.scale, self.zero), (self.bits, self.dim, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def nbytes(self) -> int:
        return self.packed.size * self.packed.dtype.itemsize + (
            self.scale.size + self.zero.size
        ) * 4


def stochastic_round(x: jax.Array, key: jax.Array) -> jax.Array:
    """Unbiased rounding: ceil w.p. frac(x), floor otherwise (paper Eq. 3)."""
    floor = jnp.floor(x)
    frac = x - floor
    u = jax.random.uniform(key, x.shape, dtype=x.dtype)
    return floor + (u < frac).astype(x.dtype)


def nearest_round(x: jax.Array, key: jax.Array | None = None) -> jax.Array:
    """Deterministic nearest rounding (paper Table 6 ablation; biased)."""
    del key
    return jnp.round(x)


def _codes_per_byte(bits: int) -> int:
    assert bits in (1, 2, 4, 8), f"unsupported bit-width {bits}"
    return 8 // bits


def pack_bits(codes: jax.Array, bits: int) -> jax.Array:
    """Pack b-bit integer codes (uint8, values < 2^b) along the last axis.

    Chunk-interleaved layout: the padded last axis of size ``dp * cpb``
    (``cpb = 8 // bits`` codes per byte, ``dp = ceil(d / cpb)``) is split
    into ``cpb`` contiguous chunks; byte ``j`` stores code ``k*dp + j`` in
    bit field ``[k*bits, (k+1)*bits)``. Pure slice/shift/or — no lane
    reshapes — so the identical layout is cheap inside Pallas TPU kernels.

    ``(..., d)`` uint8 -> ``(..., dp)`` uint8.
    """
    cpb = _codes_per_byte(bits)
    if cpb == 1:
        return codes
    d = codes.shape[-1]
    dp = -(-d // cpb)
    pad = dp * cpb - d
    if pad:
        codes = jnp.pad(codes, [(0, 0)] * (codes.ndim - 1) + [(0, pad)])
    out = codes[..., 0:dp]
    for k in range(1, cpb):
        out = out | (codes[..., k * dp:(k + 1) * dp] << jnp.uint8(k * bits))
    return out.astype(jnp.uint8)


def unpack_bits(packed: jax.Array, bits: int, dim: int) -> jax.Array:
    """Inverse of :func:`pack_bits`; returns uint8 codes of last-axis ``dim``."""
    cpb = _codes_per_byte(bits)
    if cpb == 1:
        return packed[..., :dim]
    mask = jnp.uint8(2**bits - 1)
    chunks = [
        (packed >> jnp.uint8(k * bits)) & mask for k in range(cpb)
    ]
    codes = jnp.concatenate(chunks, axis=-1)
    return codes[..., :dim]


@functools.partial(jax.jit, static_argnames=("bits", "stochastic"))
def quantize(
    x: jax.Array,
    key: jax.Array,
    *,
    bits: int = 2,
    stochastic: bool = True,
) -> QTensor:
    """Per-row uniform quantization (paper Eq. 3) + bit-pack."""
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    d = xf.shape[-1]
    bins = float(2**bits - 1)
    lo = jnp.min(xf, axis=-1, keepdims=True)
    hi = jnp.max(xf, axis=-1, keepdims=True)
    rng = hi - lo
    scale = rng / bins  # R / B
    inv = bins / jnp.maximum(rng, _EPS)
    normed = (xf - lo) * inv  # in [0, B]
    rounder = stochastic_round if stochastic else nearest_round
    codes = jnp.clip(rounder(normed, key), 0.0, bins).astype(jnp.uint8)
    return QTensor(
        packed=pack_bits(codes, bits),
        scale=scale,
        zero=lo,
        bits=bits,
        dim=d,
        dtype=orig_dtype,
    )


@jax.jit
def dequantize(q: QTensor) -> jax.Array:
    """Paper Eq. 4: ``x_hat = scale * code + zero`` restored to orig dtype."""
    codes = unpack_bits(q.packed, q.bits, q.dim).astype(jnp.float32)
    return (codes * q.scale + q.zero).astype(q.dtype)


def act_bytes(shape: tuple[int, ...], bits: int | None, dtype=jnp.float32) -> int:
    """Bytes needed to store an activation of ``shape`` at ``bits`` precision.

    ``bits=None`` means uncompressed (the FP32 baseline in paper Table 5).
    Includes the per-row scale/zero overhead for quantized storage.
    """
    n = 1
    for s in shape:
        n *= s
    if bits is None:
        return n * jnp.dtype(dtype).itemsize
    d = shape[-1]
    rows = n // d
    payload = rows * ((d * bits + 7) // 8)
    return payload + rows * 2 * 4  # scale + zero fp32 per row
