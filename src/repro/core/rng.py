"""Deterministic key derivation for stochastic rounding.

Every compressed op consumes one PRNG key. Keys are derived from the op's
*named scope* (e.g. ``"kgat/layer2/spmm"``):

    key = fold_in(fold_in(root, crc32(scope)), step)

which is deterministic given the root key — fault-tolerant replay is exact
(a restarted step reproduces the same rounding decisions) — and **stable
under program edits**: adding or removing an op changes no other op's key.
The legacy ``KeyChain`` derives keys from a positional counter instead;
inserting one op silently re-keys every op after it (changing replay), so
new code should use scopes (``repro.core.context``) and ``KeyChain`` is
kept only for explicit-kwargs call sites that predate the context API.
"""

from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp

__all__ = ["KeyChain", "step_key", "scope_hash", "scope_key"]


def scope_hash(scope: str) -> int:
    """Stable 32-bit hash of a scope path (crc32 — not Python ``hash``,
    which is salted per process and would break cross-run replay)."""
    return zlib.crc32(scope.encode("utf-8")) & 0xFFFFFFFF


def scope_key(root: jax.Array, scope: str,
              step: jax.Array | int = 0) -> jax.Array:
    """Key for one op site at one step; see module docstring."""
    return jax.random.fold_in(
        jax.random.fold_in(root, jnp.uint32(scope_hash(scope))), step)


class KeyChain:
    """Stateful (trace-time) positional key dispenser — legacy.

    Scope-derived keys (``scope_key`` / ``ActContext``) supersede this:
    the counter re-keys every downstream op when one is inserted. Still
    valid inside a single traced fn whose op list never changes.
    """

    def __init__(self, root: jax.Array):
        self._root = root
        self._n = 0

    def next(self) -> jax.Array:
        k = jax.random.fold_in(self._root, self._n)
        self._n += 1
        return k

    def split(self, n: int) -> jax.Array:
        ks = jax.vmap(lambda i: jax.random.fold_in(self._root, self._n + i))(
            jax.numpy.arange(n)
        )
        self._n += n
        return ks


def step_key(root: jax.Array, step: jax.Array | int) -> jax.Array:
    """Key for a given global step: replayable across restarts."""
    return jax.random.fold_in(root, step)
