"""Deterministic key derivation for stochastic rounding.

Every compressed op consumes one PRNG key. ``KeyChain`` derives a fresh key
per call via ``fold_in`` on a monotonically increasing counter — fully
deterministic given the root key, which makes fault-tolerant replay exact
(the restarted step reproduces the same rounding decisions).
"""

from __future__ import annotations

import jax

__all__ = ["KeyChain", "step_key"]


class KeyChain:
    """Stateful (trace-time) key dispenser. Use inside a single traced fn."""

    def __init__(self, root: jax.Array):
        self._root = root
        self._n = 0

    def next(self) -> jax.Array:
        k = jax.random.fold_in(self._root, self._n)
        self._n += 1
        return k

    def split(self, n: int) -> jax.Array:
        ks = jax.vmap(lambda i: jax.random.fold_in(self._root, self._n + i))(
            jax.numpy.arange(n)
        )
        self._n += n
        return ks


def step_key(root: jax.Array, step: jax.Array | int) -> jax.Array:
    """Key for a given global step: replayable across restarts."""
    return jax.random.fold_in(root, step)
