"""The ACT context: named scopes, per-site policies, traced residuals.

``ActContext`` is a *trace-time* object (plain Python state, never traced
itself) that gives every compressed op three things when the explicit
``key=`` / ``policy=`` kwargs are omitted:

  * a **named scope** — a ``/``-joined path like ``"kgat/layer2/spmm"``
    built from ``ctx.scope(...)`` blocks plus the op's site name;
  * a **policy** — resolved from the context's ``PolicySchedule`` by
    ``(op_kind, scope, layer)``, first matching rule wins;
  * a **stochastic-rounding key** — ``fold_in(fold_in(root, crc32(scope)),
    step)``, stable when ops are added/removed (unlike the positional
    ``KeyChain`` counter) and replay-exact across restarts.

The context also **records every residual the ops save** (scope, op kind,
shape, bits, exact-mask flag) while the function is traced, so activation-
memory accounting (``repro.core.memory``) is derived from the real ctx
chain instead of hand-maintained shape tables.

Usage — ambient (the common path)::

    with act_context(schedule, root_key=root, step=step):
        loss = bpr_loss(params, g, batch, cfg)   # ops self-resolve

or explicit per-call (``key=`` / ``policy=`` kwargs always win, so
migration is incremental).

Lifecycle: scope-name dedup (``#k`` suffixes for repeated names) and the
residual record list live on the context, so create a **fresh context per
traced function**; reuse across traces accumulates both.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterator, Sequence

import jax

from .policy import ACTPolicy, PolicySchedule, as_schedule
from .rng import scope_key

__all__ = ["SavedResidual", "ActContext", "act_context", "current_context",
           "model_context"]


@dataclasses.dataclass(frozen=True)
class SavedResidual:
    """One residual the backward pass will hold, as seen at trace time.

    bits is the *storage* width (None = uncompressed fp32 baseline);
    exact_mask marks lossless 1-bit bool masks (ReLU), which carry no
    per-row scale/zero overhead.
    """

    scope: str
    op_kind: str
    shape: tuple[int, ...]
    bits: int | None
    exact_mask: bool = False


# Ambient context stack. Plain module state: JAX traces a function on one
# thread, and contexts are entered/exited at trace time only.
_ACTIVE: list["ActContext"] = []


def current_context() -> "ActContext | None":
    return _ACTIVE[-1] if _ACTIVE else None


class ActContext:
    """See module docstring. ``schedule`` accepts a bare ``ACTPolicy``."""

    def __init__(self, schedule: PolicySchedule | ACTPolicy | None = None,
                 root_key: jax.Array | None = None, *,
                 step: jax.Array | int = 0):
        self.schedule = as_schedule(schedule) if schedule is not None else None
        self.root_key = root_key
        self.step = step
        self.records: list[SavedResidual] = []
        self._stack: list[str] = []
        self._seen: dict[str, int] = {}

    # -- ambient management -------------------------------------------------

    def __enter__(self) -> "ActContext":
        _ACTIVE.append(self)
        return self

    def __exit__(self, *exc) -> None:
        popped = _ACTIVE.pop()
        assert popped is self, "ActContext exited out of order"

    @contextlib.contextmanager
    def scope(self, name: str) -> Iterator["ActContext"]:
        """Push a scope path component for the ops traced inside."""
        self._stack.append(name)
        try:
            yield self
        finally:
            self._stack.pop()

    # -- per-site resolution ------------------------------------------------

    def scope_path(self, name: str) -> str:
        """Full scope path for a site name WITHOUT registering it.

        For call sites that need a site's policy/key ahead of the op call
        (e.g. threading a key into a shard_map body) while letting the op
        itself claim the name via ``qualify``.
        """
        return "/".join(self._stack + [name]) if self._stack else name

    def qualify(self, name: str) -> str:
        """Full scope path for a site name; repeats get ``#k`` suffixes.

        The suffix keeps keys unique when one scope name is hit twice in a
        trace while leaving every *other* site's path (hence key) alone.
        """
        full = self.scope_path(name)
        n = self._seen.get(full, 0)
        self._seen[full] = n + 1
        return full if n == 0 else f"{full}#{n}"

    def policy_for(self, op_kind: str, scope: str) -> ACTPolicy | None:
        if self.schedule is None:
            return None
        return self.schedule.resolve(op_kind, scope)

    def key_for(self, scope: str) -> jax.Array | None:
        if self.root_key is None:
            return None
        return scope_key(self.root_key, scope, self.step)

    # -- trace records ------------------------------------------------------

    def record(self, scope: str, op_kind: str, shape: Sequence[int],
               bits: int | None, *, exact_mask: bool = False) -> None:
        self.records.append(SavedResidual(
            scope=scope, op_kind=op_kind, shape=tuple(shape), bits=bits,
            exact_mask=exact_mask))

    def report(self) -> dict:
        """Price the recorded residuals (``repro.core.memory``)."""
        from .memory import activation_bytes_report

        return activation_bytes_report(self.records)

    # -- entry-point guard --------------------------------------------------

    def check_key(self, who: str) -> None:
        """Fail fast when SR randomness is needed but no root key exists.

        Silently substituting a constant key would reuse identical rounding
        noise every step, breaking the unbiasedness-in-expectation argument
        (Proposition 1 averages over independent draws).
        """
        if self.root_key is None and self.schedule is not None \
                and self.schedule.requires_key:
            raise ValueError(
                f"{who}: the active stochastic-rounding policy needs a PRNG "
                "key — pass key=, or enter act_context(..., root_key=...). "
                "(A fixed default key would replay identical rounding noise "
                "every step.)")


def act_context(schedule: PolicySchedule | ACTPolicy | None = None,
                root_key: jax.Array | None = None, *,
                step: jax.Array | int = 0) -> ActContext:
    """A fresh ``ActContext`` to be entered as the ambient context::

        with act_context(schedule, root_key, step=step) as ctx:
            ...
    """
    return ActContext(schedule, root_key, step=step)


def model_context(policy=None, key: jax.Array | None = None, *,
                  default: ACTPolicy | None = None) -> ActContext:
    """Context resolution for model entry points (``propagate`` etc.).

    Precedence: explicit kwargs beat the ambient context beats ``default``
    (FP32 when unset). With no explicit override an active ambient context
    is reused as-is; otherwise a local context is built, inheriting
    whatever the explicit kwargs leave unspecified from the ambient one —
    including its residual record list, so a recording trace still sees
    ops called with explicit overrides. Entering the returned context is
    always safe (re-entering the ambient context nests).
    """
    amb = current_context()
    if amb is not None and policy is None and key is None:
        return amb
    if policy is not None:
        schedule = as_schedule(policy)
    elif amb is not None and amb.schedule is not None:
        schedule = amb.schedule
    else:
        from .policy import FP32

        schedule = as_schedule(default if default is not None else FP32)
    root = key if key is not None else (
        amb.root_key if amb is not None else None)
    step = amb.step if amb is not None else 0
    ctx = ActContext(schedule, root, step=step)
    if amb is not None:
        # Shared sinks: the outer trace keeps collecting records, and scope
        # dedup stays global — a second model call reusing the same scope
        # names must get #k-suffixed sites (distinct SR keys, distinct
        # report entries), not silent collisions.
        ctx.records = amb.records
        ctx._seen = amb._seen
    return ctx
