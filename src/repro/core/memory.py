"""Activation-memory accounting (paper Table 5 "Act Mem" column).

Models register the shapes of the activation maps they would save per train
step; this module prices them under a given ACT policy. This is analytic
accounting over the *same* shapes XLA would buffer — on CPU we cannot read
real GPU buffers, and on TPU the dry-run's memory_analysis() provides the
device-level ground truth.
"""

from __future__ import annotations

from .policy import ACTPolicy
from .quant import act_bytes

__all__ = ["activation_bytes_report"]


def activation_bytes_report(
    shapes: dict[str, tuple[int, ...]],
    policy: ACTPolicy,
    *,
    exact_bool_masks: tuple[str, ...] = (),
) -> dict[str, float]:
    """Price a model's saved-activation shapes under ``policy``.

    shapes           : name -> activation shape (as saved for backward)
    exact_bool_masks : names stored as 1-bit exact masks regardless of policy
                       (e.g. ReLU masks)

    Returns dict with per-tensor bytes, totals, and the compression ratio
    vs the FP32 baseline (the paper's headline 7.1x at INT2).
    """
    bits = policy.bits if policy.active else None
    report: dict[str, float] = {}
    total = 0
    total_fp32 = 0
    for name, shape in shapes.items():
        fp32 = act_bytes(shape, None)
        if name in exact_bool_masks:
            b = act_bytes(shape, 1) - _row_overhead(shape)  # pure 1-bit mask
        else:
            b = act_bytes(shape, bits)
        report[name] = b
        total += b
        total_fp32 += fp32
    report["total_bytes"] = total
    report["total_fp32_bytes"] = total_fp32
    report["compression_ratio"] = total_fp32 / max(total, 1)
    return report


def _row_overhead(shape: tuple[int, ...]) -> int:
    n = 1
    for s in shape:
        n *= s
    rows = n // shape[-1]
    return rows * 8  # scale+zero fp32 per row
