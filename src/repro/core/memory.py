"""Activation-memory accounting (paper Table 5 "Act Mem" column).

Derived from the **residual trace**: while a loss function is traced under
a recording ``ActContext``, every compressed op records the residual it
saves (scope, shape, bits, exact-mask flag — ``SavedResidual``), and this
module prices those records. Footprint accounting therefore reflects what
is *actually buffered* by the real ctx chain — there are no hand-maintained
shape tables to drift (the pre-context ``activation_shapes`` functions in
the model modules are gone). This stays analytic accounting — on CPU we
cannot read real device buffers; on TPU the dry-run's ``memory_analysis()``
provides the device-level ground truth.
"""

from __future__ import annotations

from typing import Sequence

from .quant import act_bytes

__all__ = ["activation_bytes_report", "traced_activation_report",
           "publish_activation_report"]


def _mask_bytes(shape: tuple[int, ...]) -> int:
    """Exact 1-bit bool mask: b/8 payload per row, no scale/zero overhead."""
    n = 1
    for s in shape:
        n *= s
    rows = n // shape[-1]
    return rows * ((shape[-1] + 7) // 8)


def activation_bytes_report(records: Sequence) -> dict[str, float]:
    """Price a residual trace (``ActContext.records``).

    Each record carries its *own* storage width, so mixed per-site
    schedules price correctly. Returns per-scope bytes, totals, and the
    compression ratio vs the FP32 baseline of the same trace (the paper's
    headline 7.1x at INT2).
    """
    report: dict[str, float] = {}
    total = 0
    total_fp32 = 0
    for r in records:
        fp32 = act_bytes(r.shape, None)
        if r.exact_mask:
            b = _mask_bytes(r.shape)
        else:
            b = act_bytes(r.shape, r.bits)
        report[r.scope] = b
        total += b
        total_fp32 += fp32
    report["total_bytes"] = total
    report["total_fp32_bytes"] = total_fp32
    report["compression_ratio"] = total_fp32 / max(total, 1)
    return report


def traced_activation_report(fn, *args, schedule=None, key=None,
                             step=0) -> dict[str, float]:
    """Trace ``fn(*args)`` under a recording context and price the residuals.

    Runs ``jax.eval_shape`` — no FLOPs, no device buffers — inside a fresh
    ``ActContext`` so the ops self-report what they would save for the
    backward pass. ``fn`` must pick its policies up from the ambient
    context (i.e. not pass explicit ``policy=`` overrides you care about
    pricing differently).
    """
    import jax

    from .context import ActContext

    ctx = ActContext(schedule,
                     key if key is not None else jax.random.PRNGKey(0),
                     step=step)
    with ctx:
        jax.eval_shape(fn, *args)
    return activation_bytes_report(ctx.records)


def publish_activation_report(report: dict[str, float], registry=None,
                              *, prefix: str = "act") -> None:
    """Mirror an activation-bytes report into the metrics registry.

    Per-scope rows become ``act/bytes{scope=...}`` gauges; the three
    aggregates become ``act/total_bytes`` / ``act/total_fp32_bytes`` /
    ``act/compression_ratio`` — the live activation timeline the run
    summary carries (and the schema check in benchmarks reads). The obs
    import is local so this module stays free of the telemetry layer
    unless publishing is actually requested.
    """
    from repro.obs import get_registry

    reg = registry if registry is not None else get_registry()
    for scope, b in report.items():
        if scope in ("total_bytes", "total_fp32_bytes", "compression_ratio"):
            reg.gauge(f"{prefix}/{scope}").set(float(b))
        else:
            reg.gauge(f"{prefix}/bytes", scope=scope).set(float(b))
