"""ACT (activation-compressed training) policy.

The policy is a frozen (hashable) dataclass so it can ride through
``jax.custom_vjp(nondiff_argnums=...)`` and ``jax.jit(static_argnames=...)``.
"""

from __future__ import annotations

import dataclasses

__all__ = ["ACTPolicy", "FP32", "INT8", "INT4", "INT2", "INT1", "policy_for_bits"]


@dataclasses.dataclass(frozen=True)
class ACTPolicy:
    """How compressed-activation ops store their backward residuals.

    bits       : 1/2/4/8 quantized storage, or ``None`` for the exact FP32
                 baseline (paper Tables 2-5 column "FP32").
    stochastic : stochastic rounding (paper default) vs nearest rounding
                 (paper Table 6 ablation — diverges below INT8).
    enabled    : master switch; ``False`` behaves exactly like vanilla ops.
    kernel     : "jnp" reference path or "pallas" fused TPU kernels.
    """

    bits: int | None = 2
    stochastic: bool = True
    enabled: bool = True
    kernel: str = "jnp"

    def __post_init__(self):
        if self.bits is not None and self.bits not in (1, 2, 4, 8):
            raise ValueError(f"bits must be in {{1,2,4,8}} or None, got {self.bits}")
        if self.kernel not in ("jnp", "pallas"):
            raise ValueError(f"kernel must be 'jnp' or 'pallas', got {self.kernel}")

    @property
    def active(self) -> bool:
        return self.enabled and self.bits is not None

    def with_bits(self, bits: int | None) -> "ACTPolicy":
        return dataclasses.replace(self, bits=bits)


FP32 = ACTPolicy(bits=None)
INT8 = ACTPolicy(bits=8)
INT4 = ACTPolicy(bits=4)
INT2 = ACTPolicy(bits=2)
INT1 = ACTPolicy(bits=1)


def policy_for_bits(bits: int | None, *, stochastic: bool = True,
                    kernel: str = "jnp") -> ACTPolicy:
    return ACTPolicy(bits=bits, stochastic=stochastic, kernel=kernel)
