"""ACT (activation-compressed training) policies and schedules.

``ACTPolicy`` is a frozen (hashable) dataclass so it can ride through
``jax.custom_vjp(nondiff_argnums=...)`` and ``jax.jit(static_argnames=...)``.
It describes ONE op site's residual storage.

``PolicySchedule`` maps op *sites* to policies: an ordered rule table over
``(op_kind, scope glob, layer)`` resolved at trace time by the ACT context
(``repro.core.context``). A bare ``ACTPolicy`` is the uniform-schedule fast
path — every API that takes a schedule also accepts a policy (via
``as_schedule``). See DESIGN.md §6 for the resolution order.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import re

__all__ = [
    "ACTPolicy", "FP32", "INT8", "INT4", "INT2", "INT1", "policy_for_bits",
    "ScheduleRule", "PolicySchedule", "as_schedule", "scope_layer",
    "parse_schedule", "schedule_from_cli", "first_layer_int8_rest_int2",
    "SCHEDULE_PRESETS",
]


@dataclasses.dataclass(frozen=True)
class ACTPolicy:
    """How compressed-activation ops store their backward residuals.

    bits       : 1/2/4/8 quantized storage, or ``None`` for the exact FP32
                 baseline (paper Tables 2-5 column "FP32").
    stochastic : stochastic rounding (paper default) vs nearest rounding
                 (paper Table 6 ablation — diverges below INT8).
    enabled    : master switch; ``False`` behaves exactly like vanilla ops.
    kernel     : "jnp" reference path or "pallas" fused TPU kernels.
    """

    bits: int | None = 2
    stochastic: bool = True
    enabled: bool = True
    kernel: str = "jnp"

    def __post_init__(self):
        if self.bits is not None and self.bits not in (1, 2, 4, 8):
            raise ValueError(f"bits must be in {{1,2,4,8}} or None, got {self.bits}")
        if self.kernel not in ("jnp", "pallas"):
            raise ValueError(f"kernel must be 'jnp' or 'pallas', got {self.kernel}")

    @property
    def active(self) -> bool:
        return self.enabled and self.bits is not None

    @property
    def requires_key(self) -> bool:
        """True when this policy's quantizer consumes SR randomness."""
        return self.active and self.stochastic

    def with_bits(self, bits: int | None) -> "ACTPolicy":
        return dataclasses.replace(self, bits=bits)


FP32 = ACTPolicy(bits=None)
INT8 = ACTPolicy(bits=8)
INT4 = ACTPolicy(bits=4)
INT2 = ACTPolicy(bits=2)
INT1 = ACTPolicy(bits=1)


def policy_for_bits(bits: int | None, *, stochastic: bool = True,
                    kernel: str = "jnp") -> ACTPolicy:
    return ACTPolicy(bits=bits, stochastic=stochastic, kernel=kernel)


# ---------------------------------------------------------------------------
# per-site policy schedules
# ---------------------------------------------------------------------------

# a scope path component "layer<N>" tags the layer index (naming convention,
# DESIGN.md §6); "#k" suffixes are trace-time dedup of repeated scope names
# and are invisible to rule matching.
_LAYER_RE = re.compile(r"(?:^|/)layer(\d+)(?:/|$)")


def scope_layer(scope: str) -> int | None:
    """Layer index encoded in a scope path, or None."""
    m = _LAYER_RE.search(scope.split("#", 1)[0])
    return int(m.group(1)) if m else None


@dataclasses.dataclass(frozen=True)
class ScheduleRule:
    """One row of a ``PolicySchedule``; ``None`` fields match anything.

    op_kind : op class ("matmul" | "nonlin" | "rmsnorm" | "spmm" | "remat")
    scope   : fnmatch glob over the full scope path, e.g. ``"kgat/*/spmm"``
    layer   : matches the ``layer<N>`` component of the scope path
    """

    policy: ACTPolicy
    op_kind: str | None = None
    scope: str | None = None
    layer: int | None = None

    def matches(self, op_kind: str, scope: str) -> bool:
        if self.op_kind is not None and self.op_kind != op_kind:
            return False
        if self.scope is not None and not fnmatch.fnmatchcase(
                scope.split("#", 1)[0], self.scope):
            return False
        if self.layer is not None and self.layer != scope_layer(scope):
            return False
        return True


@dataclasses.dataclass(frozen=True)
class PolicySchedule:
    """Ordered ``(op_kind, scope glob, layer) -> ACTPolicy`` rule table.

    Resolution: first matching rule wins; no match falls through to
    ``default``. A uniform schedule is just ``PolicySchedule(default=pol)``
    (or pass the bare ``ACTPolicy`` — ``as_schedule`` wraps it).
    """

    rules: tuple[ScheduleRule, ...] = ()
    default: ACTPolicy = FP32

    def resolve(self, op_kind: str, scope: str) -> ACTPolicy:
        for rule in self.rules:
            if rule.matches(op_kind, scope):
                return rule.policy
        return self.default

    @classmethod
    def uniform(cls, policy: ACTPolicy) -> "PolicySchedule":
        return cls(rules=(), default=policy)

    @property
    def policies(self) -> tuple[ACTPolicy, ...]:
        return tuple(r.policy for r in self.rules) + (self.default,)

    @property
    def requires_key(self) -> bool:
        """Conservative: any reachable policy consumes SR randomness."""
        return any(p.requires_key for p in self.policies)

    @property
    def kernel(self) -> str:
        """Backend summary — "pallas" if any site routes through Pallas.

        Duck-types ``ACTPolicy.kernel`` for layout guards
        (``repro.data.csr.maybe_attach_layout``).
        """
        return "pallas" if any(p.kernel == "pallas" for p in self.policies) \
            else "jnp"


def as_schedule(policy_or_schedule) -> PolicySchedule:
    """Coerce an ``ACTPolicy`` (uniform fast path) to a ``PolicySchedule``."""
    if isinstance(policy_or_schedule, PolicySchedule):
        return policy_or_schedule
    if isinstance(policy_or_schedule, ACTPolicy):
        return PolicySchedule.uniform(policy_or_schedule)
    raise TypeError(
        f"expected ACTPolicy or PolicySchedule, got {policy_or_schedule!r}")


def first_layer_int8_rest_int2(*, stochastic: bool = True,
                               kernel: str = "jnp") -> PolicySchedule:
    """Tiered preset: sensitive first-layer sites at INT8, the rest INT2.

    First-layer SPMM residuals and transform inputs see the raw embedding
    scale and tolerate the least rounding noise; deeper sites sit behind
    contractive nonlinearities (the hot/cold tiering argument of the data-
    tiering line of work applied to ACT residuals).
    """
    mk = lambda b: ACTPolicy(bits=b, stochastic=stochastic, kernel=kernel)  # noqa: E731
    return PolicySchedule(rules=(ScheduleRule(policy=mk(8), layer=0),),
                          default=mk(2))


SCHEDULE_PRESETS = {
    "first_layer_int8_rest_int2": first_layer_int8_rest_int2,
}

_BITS_SPEC = {"fp32": None, "none": None, "int1": 1, "int2": 2, "int4": 4,
              "int8": 8, "1": 1, "2": 2, "4": 4, "8": 8}


def parse_schedule(spec: str, *, stochastic: bool = True,
                   kernel: str = "jnp") -> PolicySchedule:
    """Build a schedule from a CLI spec string.

    Accepted forms (see ``launch/train.py --schedule``):
      * a preset name          — ``first_layer_int8_rest_int2``
      * a uniform bit-width    — ``int2`` / ``8`` / ``fp32``
      * ordered rules          — comma-separated ``[kind:]glob=bits`` pairs,
        first match wins; a bare ``*=bits`` sets the default, and WITHOUT
        one unmatched sites stay FP32 (compress only what the spec names —
        no silent implicit bit-width). Example:
        ``spmm:*/layer0/*=8,*/layer0/*=4,*=2``.
    """
    spec = spec.strip()
    if spec in SCHEDULE_PRESETS:
        return SCHEDULE_PRESETS[spec](stochastic=stochastic, kernel=kernel)
    mk = lambda b: ACTPolicy(bits=b, stochastic=stochastic, kernel=kernel)  # noqa: E731
    if spec.lower() in _BITS_SPEC:
        return PolicySchedule.uniform(mk(_BITS_SPEC[spec.lower()]))
    rules: list[ScheduleRule] = []
    default = mk(None)
    for entry in spec.split(","):
        entry = entry.strip()
        if not entry:
            continue
        try:
            lhs, rhs = entry.split("=")
        except ValueError:
            raise ValueError(f"bad schedule entry {entry!r} in {spec!r} "
                             "(expected [kind:]glob=bits)") from None
        if rhs.lower() not in _BITS_SPEC:
            raise ValueError(f"bad bit-width {rhs!r} in {spec!r}")
        pol = mk(_BITS_SPEC[rhs.lower()])
        kind, glob = lhs.split(":", 1) if ":" in lhs else (None, lhs)
        if glob == "*" and kind is None:
            default = pol
        else:
            rules.append(ScheduleRule(policy=pol, op_kind=kind, scope=glob))
    return PolicySchedule(rules=tuple(rules), default=default)


def schedule_label(spec: str | None, bits: int | None) -> str:
    """The canonical CLI-level schedule string — logs AND checkpoint
    identity (``check_meta`` compares it on restore, so every entry
    point must derive it the same way)."""
    return spec or ("fp32" if not bits else f"int{bits}")


def schedule_from_cli(spec: str | None, bits: int | None, *,
                      stochastic: bool = True,
                      kernel: str = "jnp") -> PolicySchedule:
    """The shared ``--schedule`` / ``--bits`` precedence for entry points:
    a spec string wins; otherwise a uniform schedule from ``bits``
    (0/None = FP32 baseline)."""
    if spec:
        return parse_schedule(spec, stochastic=stochastic, kernel=kernel)
    return PolicySchedule.uniform(policy_for_bits(
        bits if bits else None, stochastic=stochastic, kernel=kernel))
