"""TinyKG core: activation-compressed training (ACT) for JAX.

Public API:
  quant:   quantize / dequantize / QTensor / pack_bits / unpack_bits
  act:     act_matmul / act_dense / act_relu / act_nonlin / act_rmsnorm /
           act_spmm / act_remat
  policy:  ACTPolicy + FP32/INT8/INT4/INT2/INT1 presets
  rng:     KeyChain / step_key
"""

from .act import (
    act_dense,
    act_matmul,
    act_nonlin,
    act_relu,
    act_remat,
    act_rmsnorm,
    act_spmm,
)
from .memory import activation_bytes_report
from .policy import FP32, INT1, INT2, INT4, INT8, ACTPolicy, policy_for_bits
from .quant import QTensor, act_bytes, dequantize, pack_bits, quantize, unpack_bits
from .rng import KeyChain, step_key

__all__ = [
    "ACTPolicy", "FP32", "INT8", "INT4", "INT2", "INT1", "policy_for_bits",
    "QTensor", "quantize", "dequantize", "pack_bits", "unpack_bits", "act_bytes",
    "act_matmul", "act_dense", "act_relu", "act_nonlin", "act_rmsnorm",
    "act_spmm", "act_remat",
    "KeyChain", "step_key",
    "activation_bytes_report",
]
