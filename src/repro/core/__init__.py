"""TinyKG core: activation-compressed training (ACT) for JAX.

Public API:
  quant:   quantize / dequantize / QTensor / pack_bits / unpack_bits
  act:     act_matmul / act_dense / act_relu / act_nonlin / act_rmsnorm /
           act_spmm / act_remat
  policy:  ACTPolicy + FP32/INT8/INT4/INT2/INT1 presets, PolicySchedule
           (ordered per-site rule table) + parse_schedule / presets
  context: ActContext / act_context — named scopes, schedule resolution,
           scope-keyed SR, residual trace (DESIGN.md §6)
  rng:     scope_key / step_key (KeyChain is legacy)
  memory:  activation_bytes_report / traced_activation_report over the
           residual trace
"""

from .act import (
    act_dense,
    act_matmul,
    act_nonlin,
    act_relu,
    act_remat,
    act_rmsnorm,
    act_spmm,
)
from .context import (
    ActContext,
    SavedResidual,
    act_context,
    current_context,
    model_context,
)
from .memory import activation_bytes_report, traced_activation_report
from .policy import (
    FP32,
    INT1,
    INT2,
    INT4,
    INT8,
    ACTPolicy,
    PolicySchedule,
    ScheduleRule,
    as_schedule,
    first_layer_int8_rest_int2,
    parse_schedule,
    policy_for_bits,
)
from .quant import QTensor, act_bytes, dequantize, pack_bits, quantize, unpack_bits
from .rng import KeyChain, scope_hash, scope_key, step_key

__all__ = [
    "ACTPolicy", "FP32", "INT8", "INT4", "INT2", "INT1", "policy_for_bits",
    "PolicySchedule", "ScheduleRule", "as_schedule", "parse_schedule",
    "first_layer_int8_rest_int2",
    "ActContext", "SavedResidual", "act_context", "current_context",
    "model_context",
    "QTensor", "quantize", "dequantize", "pack_bits", "unpack_bits", "act_bytes",
    "act_matmul", "act_dense", "act_relu", "act_nonlin", "act_rmsnorm",
    "act_spmm", "act_remat",
    "KeyChain", "step_key", "scope_key", "scope_hash",
    "activation_bytes_report", "traced_activation_report",
]
